"""Serving-engine acceptance: bucket round-trips, halo-correct tiling,
executable-cache accounting, micro-batching, and plan/raw-pipeline equality.

The load-bearing invariants:

* every service route (bucketed, tiled) is BIT-exact against running the
  same op/plan directly on the unpadded image — including SEs larger than
  the halo-free tile interior;
* the ``document_cleanup`` plan and ``data/images.py::cleanup_batch`` are
  the same computation;
* the executable cache compiles exactly once per (bucket, op, se) and its
  counters say so.
"""
import time
from concurrent.futures import Future

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import closing, dilate, erode, gradient, opening
from repro.core.dispatch import DispatchPolicy, resolve_interpret
from repro.data.images import cleanup_batch
from repro.serve.morph import (
    MicroBatcher,
    MorphService,
    ServiceConfig,
    build_executor,
    choose_bucket,
    get_plan,
    pad_to_bucket,
    run_tiled,
    single_op_plan,
)
from repro.serve.morph.plans import Plan, Step

RNG = np.random.default_rng(7)

CORE_OPS = {
    "erode": erode,
    "dilate": dilate,
    "opening": opening,
    "closing": closing,
    "gradient": gradient,
}


def rand(shape, dtype=np.uint8):
    if np.issubdtype(dtype, np.floating):
        return RNG.standard_normal(shape).astype(dtype)
    info = np.iinfo(dtype)
    return RNG.integers(info.min, info.max, shape, dtype=dtype)


def tiled_execute(plan):
    ex = build_executor(plan)
    return lambda tiles, rects: ex(jnp.asarray(tiles), jnp.asarray(rects))


# --------------------------------------------------------------------- buckets
def test_choose_bucket_smallest_fit():
    ladder = ((64, 128), (128, 128), (256, 256))
    assert choose_bucket(60, 100, ladder) == (64, 128)
    assert choose_bucket(64, 128, ladder) == (64, 128)
    assert choose_bucket(65, 100, ladder) == (128, 128)
    assert choose_bucket(300, 10, ladder) is None  # -> tiled route


def test_pad_to_bucket_preserves_data():
    img = rand((50, 70))
    padded = pad_to_bucket(img, (64, 128))
    assert padded.shape == (64, 128)
    np.testing.assert_array_equal(padded[:50, :70], img)


@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
@pytest.mark.parametrize("op", ["erode", "dilate", "opening", "closing", "gradient"])
def test_bucket_padding_round_trip_bit_exact(op, dtype):
    """Pad-to-bucket -> masked execute -> crop == the unpadded op, for every
    op (composed ones are the hard case: one fill value can't serve both
    min and max stages — the per-stage masking must)."""
    img = rand((47, 61), dtype)
    with MorphService(ServiceConfig(buckets=((64, 128),), window_ms=1.0)) as svc:
        got = svc.run(img, op=op, se=(5, 7))
    want = np.asarray(CORE_OPS[op](img, (5, 7)))
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------------- plans
def test_plan_halo_sums_expanded_wings():
    plan = get_plan("document_cleanup")
    # opening(3,3)=2*1, closing(5,5)=2*2, gradient(3,3)=1 -> 7 per axis
    assert plan.halo() == (7, 7)
    assert single_op_plan("erode", (9, 5)).halo() == (4, 2)
    assert single_op_plan("opening", (3, 7)).halo() == (2, 6)


def test_document_cleanup_plan_matches_cleanup_batch():
    img = rand((70, 90))
    with MorphService(ServiceConfig(buckets=((128, 128),), window_ms=1.0)) as svc:
        res = svc.run_plan(img, "document_cleanup")
    clean, edges = cleanup_batch(img[None])
    np.testing.assert_array_equal(res["clean"], np.asarray(clean[0]))
    np.testing.assert_array_equal(res["edges"], np.asarray(edges[0]))
    assert res["edges"].dtype == np.uint8


def test_kernel_and_jnp_backends_agree():
    img = rand((40, 70))
    plan = get_plan("document_cleanup")
    rect = jnp.asarray([[0, 40, 0, 70]], dtype=jnp.int32)
    x = jnp.asarray(img[None])
    a = build_executor(plan, backend="jnp")(x, rect)
    b = build_executor(plan, backend="kernel", interpret=True)(x, rect)
    for name in ("clean", "edges"):
        np.testing.assert_array_equal(np.asarray(a[name]), np.asarray(b[name]))


def test_gradient_plan_widens_integers():
    img = rand((30, 40))
    with MorphService(ServiceConfig(buckets=((64, 128),), window_ms=1.0)) as svc:
        got = svc.run(img, op="gradient", se=(3, 3))
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, np.asarray(gradient(img, (3, 3))))


# ---------------------------------------------------------------------- tiling
@pytest.mark.parametrize("interior", [(16, 16), (32, 48), (64, 64)])
@pytest.mark.parametrize("se", [(3, 3), (9, 5)])
def test_tiled_vs_untiled_bit_exact(interior, se):
    img = rand((75, 83))
    plan = single_op_plan("erode", se)
    outs = run_tiled(img, plan, tiled_execute(plan),
                     tile_interior=interior, launch_batch=4)
    np.testing.assert_array_equal(outs["out"], np.asarray(erode(img, se)))


def test_tiled_se_larger_than_tile_interior():
    """The halo makes the extended tile big enough even when the SE dwarfs
    the halo-free interior."""
    img = rand((40, 52))
    plan = single_op_plan("gradient", (11, 9))
    assert plan.halo() == (5, 4)
    outs = run_tiled(img, plan, tiled_execute(plan),
                     tile_interior=(8, 8), launch_batch=8)
    np.testing.assert_array_equal(outs["out"], np.asarray(gradient(img, (11, 9))))


def test_tiled_full_plan_bit_exact():
    img = rand((90, 110))
    plan = get_plan("document_cleanup")
    outs = run_tiled(img, plan, tiled_execute(plan),
                     tile_interior=(32, 32), launch_batch=4)
    clean, edges = cleanup_batch(img[None])
    np.testing.assert_array_equal(outs["clean"], np.asarray(clean[0]))
    np.testing.assert_array_equal(outs["edges"], np.asarray(edges[0]))


def test_tile_gather_is_device_resident():
    """Regression for the ROADMAP "streamed tile gather" item: halo tiles
    are assembled with device-side dynamic_slice from one padded device
    copy — not host numpy — and the stitched seams stay bit-exact on a
    shape divisible by neither the interior nor the launch batch."""
    import jax

    from repro.serve.morph.tiling import extract_tiles

    img = rand((71, 93))
    plan = get_plan("document_cleanup")
    tiles, rects, interiors = extract_tiles(img, plan, (32, 32))
    assert isinstance(tiles, jax.Array)  # gathered on device, no host copy
    gh, gw = plan.halo()
    assert tiles.shape[1:] == (32 + 2 * gh, 32 + 2 * gw)
    # seam exactness through the full service tiled route
    outs = run_tiled(img, plan, tiled_execute(plan),
                     tile_interior=(32, 32), launch_batch=4)
    clean, edges = cleanup_batch(img[None])
    np.testing.assert_array_equal(outs["clean"], np.asarray(clean[0]))
    np.testing.assert_array_equal(outs["edges"], np.asarray(edges[0]))


def test_executor_aux_reports_bounded_iters():
    """with_aux=True surfaces BoundedIter convergence depth; plans without
    bounded iteration report a zero budget."""
    from repro.morph import Var, X, reconstruct_by_dilation_expr, to_plan

    plan = to_plan(
        reconstruct_by_dilation_expr(
            X.erode((7, 7)), Var("x"), iters=32, until_stable=False
        ),
        name="aux_recon",
    )
    x = jnp.asarray(rand((24, 24))[None])
    rect = jnp.asarray(np.array([[0, 24, 0, 24]], np.int32))
    outs, aux = build_executor(plan, with_aux=True)(x, rect)
    ref = build_executor(plan)(x, rect)  # default shape: no aux
    np.testing.assert_array_equal(np.asarray(outs["out"]), np.asarray(ref["out"]))
    assert int(aux["iters_budget"]) == 32
    assert 0 < int(aux["iters_used"]) <= 32
    plain = single_op_plan("erode", (3, 3))
    _, aux2 = build_executor(plain, with_aux=True)(x, rect)
    assert int(aux2["iters_budget"]) == 0


def test_service_routes_oversized_images_to_tiling():
    img = rand((200, 150))
    with MorphService(
        ServiceConfig(buckets=((64, 128),), tile_interior=(64, 64),
                      max_tiles_per_launch=4, window_ms=1.0)
    ) as svc:
        got = svc.run(img, op="closing", se=(5, 5))
        stats = svc.stats()
    np.testing.assert_array_equal(got, np.asarray(closing(img, (5, 5))))
    assert stats["tiled_requests"] == 1


# ----------------------------------------------------------------------- cache
def test_cache_one_compile_per_bucket_op_se():
    """N same-bucket requests of varying (h, w) compile exactly once per
    (bucket, op, se); a second wave is all hits."""
    with MorphService(
        ServiceConfig(buckets=((64, 128),), max_batch=8, window_ms=1000.0)
    ) as svc:
        for wave in range(2):
            futs = [
                svc.submit(rand((40 + i, 60 + i)), op="erode", se=(3, 3))
                for i in range(8)  # == max_batch -> dispatches immediately
            ]
            [f.result() for f in futs]
            snap = svc.cache.snapshot()
            assert snap["misses"] == 1, snap
        futs = [svc.submit(rand((40, 60)), op="dilate", se=(5, 5)) for _ in range(8)]
        [f.result() for f in futs]
        snap = svc.cache.snapshot()
    assert snap["misses"] == 2, snap  # one more for the new (op, se)
    assert snap["hits"] >= 1


def test_cache_eviction_counter():
    with MorphService(
        ServiceConfig(buckets=((64, 128),), max_batch=1, window_ms=1.0,
                      cache_size=1)
    ) as svc:
        svc.run(rand((30, 40)), op="erode", se=(3, 3))
        svc.run(rand((30, 40)), op="dilate", se=(3, 3))
        snap = svc.cache.snapshot()
    assert snap["evictions"] >= 1
    assert snap["size"] <= 1


def test_policy_change_is_a_new_cache_key():
    imgs = rand((30, 40))
    cfg = ServiceConfig(buckets=((64, 128),), max_batch=1, window_ms=1.0)
    with MorphService(cfg) as svc:
        svc.run(imgs, op="erode", se=(3, 3))
        misses_a = svc.cache.snapshot()["misses"]
    with MorphService(
        ServiceConfig(buckets=((64, 128),), max_batch=1, window_ms=1.0,
                      policy=DispatchPolicy(w0_fused=3))
    ) as svc:
        svc.run(imgs, op="erode", se=(3, 3))
        misses_b = svc.cache.snapshot()["misses"]
    assert misses_a == misses_b == 1  # separate services, but token differs
    assert DispatchPolicy().cache_token() != DispatchPolicy(w0_fused=3).cache_token()


# --------------------------------------------------------------------- batcher
def test_batcher_coalesces_concurrent_requests():
    with MorphService(
        ServiceConfig(buckets=((64, 128),), max_batch=16, window_ms=200.0)
    ) as svc:
        svc.run(rand((30, 40)), op="erode", se=(3, 3))  # warm the executable
        futs = [svc.submit(rand((30, 40)), op="erode", se=(3, 3)) for _ in range(16)]
        [f.result() for f in futs]
        stats = svc.stats()
    assert stats["requests"] == 17
    # 16 concurrent requests ride in at most a few batches, not 16
    assert stats["batches"] <= 4
    assert stats["mean_batch"] > 1.0


def test_batcher_error_fans_out_to_futures():
    def boom(key, reqs):
        raise RuntimeError("executor exploded")

    class Req:
        def __init__(self):
            self.key = "k"
            self.future = Future()

    b = MicroBatcher(boom, max_batch=4, window_s=0.001)
    reqs = [Req() for _ in range(3)]
    for r in reqs:
        b.submit(r)
    for r in reqs:
        with pytest.raises(RuntimeError, match="executor exploded"):
            r.future.result(timeout=10)
    b.close()


def test_batcher_flush_and_close_drain_everything():
    done = []

    class Req:
        def __init__(self, i):
            self.key = "k"
            self.future = Future()
            self.i = i

    def execute(key, reqs):
        time.sleep(0.01)
        for r in reqs:
            done.append(r.i)
            r.future.set_result(r.i)

    b = MicroBatcher(execute, max_batch=4, window_s=0.05)
    for i in range(10):
        b.submit(Req(i))
    assert b.flush(timeout=30)
    b.close()
    assert sorted(done) == list(range(10))


def test_batch_results_match_request_order():
    imgs = [rand((25 + i, 30 + i)) for i in range(6)]
    with MorphService(
        ServiceConfig(buckets=((64, 128),), max_batch=6, window_ms=500.0)
    ) as svc:
        results = svc.run_batch(imgs, single_op_plan("erode", (3, 3)))
    for img, got in zip(imgs, results):
        assert got.shape == img.shape
        np.testing.assert_array_equal(got, np.asarray(erode(img, (3, 3))))


def test_submit_rejects_batched_input():
    with MorphService(ServiceConfig(buckets=((64, 128),))) as svc:
        with pytest.raises(ValueError, match="single"):
            svc.submit(rand((2, 30, 40)))


# ------------------------------------------------------------------- resolver
def test_resolve_interpret_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    assert resolve_interpret(None, DispatchPolicy(interpret=False)) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert resolve_interpret(None) is True
    # explicit argument and policy both beat the env var
    assert resolve_interpret(False) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert resolve_interpret(None, DispatchPolicy(interpret=True)) is True


def test_custom_plan_registration_and_multi_output():
    plan = Plan(
        "open_then_edges",
        (
            Step("opening", (3, 3), save_as="opened"),
            Step("gradient", (3, 3), save_as="edges"),
        ),
    )
    img = rand((40, 50))
    with MorphService(ServiceConfig(buckets=((64, 128),), window_ms=1.0)) as svc:
        res = svc.run_plan(img, plan)
    o = opening(img, (3, 3))
    np.testing.assert_array_equal(res["opened"], np.asarray(o))
    np.testing.assert_array_equal(res["edges"], np.asarray(gradient(o, (3, 3))))
