"""End-to-end driver #3 — batched serving across model families.

Generates from a dense LM, an attention-free RWKV (O(1) state), and the
enc-dec Whisper (cross-attention KV prefill), all through the same engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import get_config
from repro.models.model import init_params
from repro.serve import generate

rng = np.random.default_rng(0)

for arch in ("qwen1.5-0.5b", "rwkv6-7b", "whisper-medium"):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 8), dtype=np.int32))
    ctx = None
    if cfg.family == "encdec":
        ctx = jnp.asarray(
            0.01 * rng.standard_normal((4, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    t0 = time.perf_counter()
    toks = generate(cfg, params, prompt, max_new_tokens=12, context=ctx)
    toks = np.asarray(toks)
    dt = time.perf_counter() - t0
    print(f"{arch:>16} [{cfg.family}]: {toks.shape} in {dt:.2f}s — "
          f"sample {toks[0][:8].tolist()}")
print("OK: three families served through one engine")
