"""End-to-end driver #2 — train a (reduced) LM for a few hundred steps with
the full substrate: data pipeline, AdamW, checkpointing, watchdog.

The assignment's '~100M model for a few hundred steps' cell: qwen1.5-0.5b
at reduced width is ~1M params on CPU; pass --full-width to train the
true 0.5B config (slow on CPU). Loss is expected to drop well below the
ln(V) uniform floor thanks to the bigram structure in the synthetic data.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

from repro.data import TokenPipeline, TokenPipelineConfig
from repro.models.config import get_config
from repro.train import Trainer, TrainLoopConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

cfg = get_config("qwen1.5-0.5b").reduced()
data = TokenPipeline(TokenPipelineConfig(
    vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))

with tempfile.TemporaryDirectory() as ckpt_dir:
    loop = TrainLoopConfig(
        total_steps=args.steps,
        warmup_steps=args.steps // 10,
        peak_lr=1e-3,
        checkpoint_every=100,
        checkpoint_dir=ckpt_dir,
        log_every=25,
    )
    trainer = Trainer(cfg, loop, data)
    metrics = trainer.run()
    print(f"final metrics: {metrics}")
    print(f"stragglers flagged: {trainer.straggler_flags}")
    assert metrics["loss"] < 6.0, "loss should beat the uniform floor"
    print("OK: loss beat the uniform floor — training works end to end")
