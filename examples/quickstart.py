"""Quickstart: the paper's morphology API in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DispatchPolicy,
    closing,
    dilate,
    erode,
    gradient,
    morph_1d,
    opening,
)
from repro.kernels import erode2d_tpu, transpose_tiled

# An 800x600 8-bit grayscale image, like the paper's experiments.
rng = np.random.default_rng(0)
img = jnp.asarray(rng.integers(0, 256, (600, 800), dtype=np.uint8))

# 2-D erosion/dilation with a flat rectangular SE — separable, hybrid
# vHGW / linear dispatch under the hood (paper §5.3).
e = erode(img, se=(5, 7))
d = dilate(img, se=(5, 7))
print("erode/dilate:", e.shape, e.dtype, "| duality holds:",
      bool(jnp.all(e == 255 - dilate(255 - img, (5, 7)))))

# Derived operators.
print("opening<=x<=closing:",
      bool(jnp.all(opening(img, (9, 9)) <= img)),
      bool(jnp.all(closing(img, (9, 9)) >= img)))
print("gradient max:", int(gradient(img, (3, 3)).max()))

# Explicit method choice (the paper's two algorithms + the tree ladder).
for method in ("linear", "vhgw", "linear_tree"):
    out = morph_1d(img, 31, axis=-2, op="min", method=method)
    print(f"morph_1d[{method}]", out.shape)

# Hybrid dispatch policy: paper's Exynos thresholds or machine-calibrated.
print("paper policy:", DispatchPolicy.paper())
print("calibrated:  ", DispatchPolicy.calibrated())

# The Pallas TPU kernels (interpret=True executes them on CPU).
ek = erode2d_tpu(img, se=(5, 7))
print("pallas erode matches jnp:", bool(jnp.all(ek == e)))
t = transpose_tiled(img)
print("pallas 128x128-tiled transpose:", t.shape)

# Derived operators (paper §2: "other morphological operations can be
# expressed via erosion, dilation and arithmetical operations").
from repro.core import granulometry, h_maxima, occo, reconstruct_by_dilation

smoothed = occo(img, (3, 3))                     # salt+pepper remover
marker = jnp.clip(img.astype(jnp.int32) - 60, 0, None).astype(jnp.uint8)
recon = reconstruct_by_dilation(marker, img)     # geodesic reconstruction
spectrum = granulometry(img, sizes=(3, 5, 9, 15))
print("occo:", smoothed.shape, "| reconstruction <= mask:",
      bool(jnp.all(recon <= img)), "| pattern spectrum:",
      [round(float(v), 4) for v in spectrum])
