"""End-to-end driver #5 — the serving tier as a deployable fleet: document
cleanup over the ingress wire protocol.

Spawns two real worker *processes* (the same ``python -m
repro.serve.ingress.worker`` entry point production would run), routes
through a :class:`Frontier` (crc32 plan/bucket/dtype affinity, per-worker
breakers), and exposes the whole fleet on one client address via
``Frontier.serve()`` — the recursive composition::

    IngressClient -> WorkerHost(Frontier) -> Connection -> WorkerHost(worker)

Every remote result is compared bit-for-bit against a direct in-process
``MorphService``: the wire adds a process boundary, not a numerics
boundary. The fleet-wide ``stats()`` at the end is merged from each
worker's metrics registry over the same protocol.

    PYTHONPATH=src python examples/remote_cleanup.py
"""
import time

import numpy as np

from repro.serve.ingress import Frontier, IngressClient, spawn_worker
from repro.serve.morph import MorphService, ServiceConfig

BUCKET = (128, 128)
rng = np.random.default_rng(0)
imgs = [rng.integers(0, 256, (100 + 4 * i, 120), dtype=np.uint8)
        for i in range(8)]

# ------------------------------------------------------------ reference path
with MorphService(ServiceConfig(buckets=(BUCKET,))) as direct:
    refs = [direct.run_plan(im, "document_cleanup") for im in imgs]

# ---------------------------------------------------------------- the fleet
workers = []
try:
    for i in range(2):
        workers.append(spawn_worker(
            {"buckets": [list(BUCKET)], "window_ms": 2.0}, worker_id=i,
        ))
    addrs = [addr for _, addr in workers]
    print(f"fleet: 2 worker processes at {addrs}")

    with Frontier(addrs, buckets=(BUCKET,)) as front:
        edge = front.serve()  # one address for clients, same protocol
        try:
            with IngressClient(edge.address) as client:
                client.run_plan(imgs[0], "document_cleanup")  # warm
                t0 = time.perf_counter()
                futures = [client.submit_plan(im, "document_cleanup")
                           for im in imgs]
                results = [f.result() for f in futures]
                dt = time.perf_counter() - t0
                stats = client.stats()
        finally:
            edge.close()

    for got, ref in zip(results, refs):
        for k in ref:
            np.testing.assert_array_equal(got[k], np.asarray(ref[k]))
    print(f"remote : {dt*1e3:.1f} ms for {len(imgs)} requests "
          f"({len(imgs)/dt:.1f} img/s) — bit-exact vs the direct service")
    print(f"fleet  : {stats['workers']} workers "
          f"({stats['healthy_workers']} healthy), "
          f"{stats['requests']} routed requests, "
          f"p99 {stats['p99_ms']:.1f} ms, "
          f"cache hit rate {stats['cache']['hit_rate']}")
finally:
    for proc, _ in workers:
        proc.kill()
        proc.wait(timeout=60)
