"""End-to-end driver #1 — the paper's technique in production: batched
document-image cleanup feeding the stub vision tower.

Pipeline: synthetic noisy scans -> opening (salt removal) -> closing
(stroke healing) -> morphological gradient (edge features) -> dilation
max-pool -> patch embeddings (what llama-3.2-vision's cross-attention
consumes).

The cleanup stage runs twice, side by side:

* the **direct** path (`data/images.py::cleanup_batch`) — one jitted call
  over the whole pre-assembled batch;
* the **service** path (`serve/morph`) — each scan submitted as its own
  request, the micro-batcher coalescing them into bucket-padded stacks the
  way live traffic would arrive;

and the results are compared bit-for-bit (the `document_cleanup` plan IS
the cleanup_batch chain).

    PYTHONPATH=src python examples/document_cleanup.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.data import (
    ImagePipelineConfig,
    cleanup_batch,
    patch_embed_stub,
    synth_documents,
)
from repro.serve.morph import MorphService, ServiceConfig

cfg = ImagePipelineConfig(height=600, width=800, noise_frac=0.03)
batch = 8

imgs = synth_documents(cfg, batch)
print(f"input: {imgs.shape} u8, salt pixels: {(imgs == 255).sum()}")

# ---------------------------------------------------------------- direct path
t0 = time.perf_counter()
clean, edges = cleanup_batch(imgs)
clean.block_until_ready()
dt = time.perf_counter() - t0
print(f"direct : {dt*1e3:.1f} ms for {batch} images "
      f"({batch/dt:.1f} img/s), salt after: {(np.asarray(clean) == 255).sum()}")

# --------------------------------------------------------------- service path
svc_cfg = ServiceConfig(buckets=((608, 896),), max_batch=batch, window_ms=2.0)
with MorphService(svc_cfg) as svc:
    svc.run_batch(list(imgs), "document_cleanup")  # warm the executable cache
    t0 = time.perf_counter()
    futures = [svc.submit_plan(img, "document_cleanup") for img in imgs]
    results = [f.result() for f in futures]
    dt_svc = time.perf_counter() - t0
    stats = svc.stats()
print(f"service: {dt_svc*1e3:.1f} ms for {batch} single-image requests "
      f"({batch/dt_svc:.1f} img/s) — p50 {stats['p50_ms']:.1f} ms, "
      f"p99 {stats['p99_ms']:.1f} ms, mean batch {stats['mean_batch']:.1f}, "
      f"cache hit-rate {stats['cache']['hit_rate']:.2f}")

same_clean = all(
    np.array_equal(r["clean"], np.asarray(clean[i])) for i, r in enumerate(results)
)
same_edges = all(
    np.array_equal(r["edges"], np.asarray(edges[i])) for i, r in enumerate(results)
)
print(f"service == direct: clean {same_clean}, edges {same_edges} "
      f"(bucket-padded, micro-batched, bit-exact)")

# ------------------------------------------------- unified expression API
# The same chain as one expression graph: build once, lower anywhere. The
# service compiles the identical graph the direct path jits, and iterative
# operators (reconstruction) ride the same route via BoundedIter plans.
from repro.morph import X, lower_xla, reconstruct_by_dilation_expr, to_plan

chain = X.opening((3, 3)).closing((5, 5))
edges_expr = chain.gradient((3, 3)).astype("uint8")
plan = to_plan({"clean": chain, "edges": edges_expr}, name="cleanup_expr")
print(f"expr plan: halo={plan.halo()} outputs={plan.output_names()}")

direct_expr = lower_xla({"clean": chain, "edges": edges_expr})(jnp.asarray(imgs))
with MorphService(svc_cfg) as svc:
    res = svc.run_plan(imgs[0], plan)
same = np.array_equal(res["edges"], np.asarray(direct_expr["edges"][0]))
print(f"expr-built plan == direct lowering: {same}")

recon = reconstruct_by_dilation_expr(X.erode((7, 7)), X, (3, 3),
                                     iters=64, until_stable=False)
with MorphService(svc_cfg) as svc:
    opened = svc.run_expr(imgs[0], recon, name="open_by_reconstruction")
print(f"served opening-by-reconstruction (bounded 64 iters): {opened.shape} "
      f"{opened.dtype} — iterative operators are servable now")

# ------------------------------------------------- binary-mask stage (RLE)
# Downstream OCR wants a foreground mask, not grayscale: threshold the
# cleaned scans to ink masks and open away residual specks. Boolean plans
# route through the per-request density gate — sparse ink masks execute in
# the run domain (cost ∝ runs, not pixels) while the same plan on a dense
# mask stays on the dense path; both land bit-identical to lower_xla.
from repro.morph import lower_rle
from repro.rle import estimate_run_density

mask_expr = X.opening((3, 3))
ink = np.asarray(clean) < 128  # ink is dark; salt is already opened away
dens = [estimate_run_density(m) for m in ink]
direct_mask = np.asarray(lower_xla(mask_expr)(jnp.asarray(ink)))
rle_mask = lower_rle(mask_expr)(ink)
mask_plan = to_plan(mask_expr, name="ink_mask")
with MorphService(svc_cfg) as svc:
    served_mask = svc.run_batch(list(ink), mask_plan)
    mstats = svc.stats()
same_rle = np.array_equal(rle_mask, direct_mask)
same_served = all(
    np.array_equal(served_mask[i], direct_mask[i]) for i in range(batch)
)
assert same_rle and same_served, "binary-mask paths diverged"
print(f"ink masks: run density p50 {np.median(dens):.4f} — served "
      f"{mstats['repr']['rle']}/{batch} via RLE, "
      f"{mstats['repr']['dense']}/{batch} dense; RLE == dense == served: "
      f"{same_rle and same_served} (bit-exact)")

emb = patch_embed_stub(jnp.asarray(clean), d_model=256, n_tokens=256)
print(f"vision-tower stub tokens: {emb.shape} "
      f"(these feed VLM cross-attention layers)")

# quality proxy: stroke pixels survive, salt doesn't
stroke_before = int(((np.asarray(imgs) > 5) & (np.asarray(imgs) < 70)).sum())
stroke_after = int(((np.asarray(clean) > 5) & (np.asarray(clean) < 70)).sum())
print(f"stroke retention: {stroke_after / max(stroke_before,1):.2f} "
      f"(opening removes noise, closing heals strokes)")
