"""End-to-end driver #1 — the paper's technique in production: batched
document-image cleanup feeding the stub vision tower.

Pipeline: synthetic noisy scans -> opening (salt removal) -> closing
(stroke healing) -> morphological gradient (edge features) -> dilation
max-pool -> patch embeddings (what llama-3.2-vision's cross-attention
consumes).

    PYTHONPATH=src python examples/document_cleanup.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.data import (
    ImagePipelineConfig,
    cleanup_batch,
    patch_embed_stub,
    synth_documents,
)

cfg = ImagePipelineConfig(height=600, width=800, noise_frac=0.03)
batch = 8

imgs = synth_documents(cfg, batch)
print(f"input: {imgs.shape} u8, salt pixels: {(imgs == 255).sum()}")

t0 = time.perf_counter()
clean, edges = cleanup_batch(imgs)
clean.block_until_ready()
dt = time.perf_counter() - t0
print(f"cleanup: {dt*1e3:.1f} ms for {batch} images "
      f"({batch/dt:.1f} img/s), salt after: {(np.asarray(clean) == 255).sum()}")

emb = patch_embed_stub(jnp.asarray(clean), d_model=256, n_tokens=256)
print(f"vision-tower stub tokens: {emb.shape} "
      f"(these feed VLM cross-attention layers)")

# quality proxy: stroke pixels survive, salt doesn't
stroke_before = int(((np.asarray(imgs) > 5) & (np.asarray(imgs) < 70)).sum())
stroke_after = int(((np.asarray(clean) > 5) & (np.asarray(clean) < 70)).sum())
print(f"stroke retention: {stroke_after / max(stroke_before,1):.2f} "
      f"(opening removes noise, closing heals strokes)")
